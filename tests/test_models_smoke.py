"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward/train step on CPU, shape + finiteness asserts, and AR==NAR
consistency at the logits level."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, PAPER_MODELS, get_config
from repro.core.precision import BF16, FP32
from repro.models import frontends, lm, vit
from repro.sharding.plan import UNSHARDED

ARCHS = sorted(ASSIGNED)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_forward_smoke(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    batch = frontends.make_batch(cfg, "train", 2, 32 + (cfg.n_patches or 0))
    loss, metrics = lm.forward_train(params, batch, plan=UNSHARDED, cfg=cfg,
                                     policy=FP32)
    assert np.isfinite(float(loss))
    # ln(vocab) ballpark for random init
    assert 0.5 * np.log(cfg.vocab) < float(metrics["ce"]) < 2 * np.log(cfg.vocab)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """AR decode must track NAR prefill: greedy tokens agree or are
    numerical ties (checked against the reference prefill logits)."""
    cfg = get_config(arch).reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    S, max_seq, steps = 16, 32, 3
    batch = frontends.make_batch(cfg, "prefill", 2,
                                 S + (cfg.n_patches or 0))
    tok, caches, pos = lm.forward_prefill(params, batch, plan=UNSHARDED,
                                          cfg=cfg, policy=FP32,
                                          max_seq=max_seq)
    toks = [tok]
    t, p = tok, pos
    for _ in range(steps):
        t, caches = lm.forward_decode(params, t, p, caches, plan=UNSHARDED,
                                      cfg=cfg, policy=FP32)
        p = p + 1
        toks.append(t)
    # reference: fresh prefill over prompt + generated prefix
    for i in range(1, steps + 1):
        ext = jnp.concatenate(
            [batch["tokens"]] + [x[:, None] for x in toks[:i]], axis=1)
        b2 = dict(batch)
        b2["tokens"] = ext
        tref, _, _ = lm.forward_prefill(params, b2, plan=UNSHARDED, cfg=cfg,
                                        policy=FP32, max_seq=max_seq)
        exact = np.asarray(tref == toks[i])
        if not exact.all():
            # tolerate fp ties: the decode token's logit must be within tol
            # of the argmax logit under the reference forward
            from repro.core.embedding import logits_local
            from repro.models.lm import _embed_sequence, _run_segments_train, _last_position
            x, _, _ = _embed_sequence(params, b2, plan=UNSHARDED, cfg=cfg,
                                      policy=FP32, with_labels=False)
            memory = None
            if cfg.enc_schedule:
                x2 = lm._run_encoder(params, b2, plan=UNSHARDED, cfg=cfg,
                                     policy=FP32)
                memory = x2
            xs, _ = _run_segments_train(params, x, plan=UNSHARDED, cfg=cfg,
                                        policy=FP32, memory=memory,
                                        memory_len=cfg.enc_seq_padded)
            from repro.kernels import ops
            xs = ops.norm(xs, params["final_norm"], cfg.norm)
            xl = _last_position(xs, UNSHARDED)
            z, _ = logits_local(xl, params["embedding"]["unemb"],
                                plan=UNSHARDED, cfg=cfg, policy=FP32)
            z = np.asarray(z)
            got = z[np.arange(z.shape[0]), np.asarray(toks[i])]
            gap = z.max(-1) - got
            assert (gap < 1e-3).all(), (arch, i, gap)


def test_vlm_patch_prefix_changes_output():
    cfg = get_config("internvl2-76b").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    batch = frontends.make_batch(cfg, "train", 2, 16 + cfg.n_patches)
    l1, _ = lm.forward_train(params, batch, plan=UNSHARDED, cfg=cfg,
                             policy=FP32)
    batch2 = dict(batch)
    batch2["patches"] = batch["patches"] * 0 + 1.0
    l2, _ = lm.forward_train(params, batch2, plan=UNSHARDED, cfg=cfg,
                             policy=FP32)
    assert float(l1) != float(l2)


def test_whisper_cross_attention_uses_frames():
    cfg = get_config("whisper-base").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    batch = frontends.make_batch(cfg, "train", 2, 16)
    l1, _ = lm.forward_train(params, batch, plan=UNSHARDED, cfg=cfg,
                             policy=FP32)
    batch2 = dict(batch)
    batch2["frames"] = batch["frames"] * 0
    l2, _ = lm.forward_train(params, batch2, plan=UNSHARDED, cfg=cfg,
                             policy=FP32)
    assert float(l1) != float(l2)


@pytest.mark.parametrize("policy", [FP32, BF16])
def test_policies_finite(policy):
    cfg = get_config("mixtral-8x7b").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    params = policy.cast_params(params)
    batch = frontends.make_batch(cfg, "train", 2, 32)
    loss, _ = lm.forward_train(params, batch, plan=UNSHARDED, cfg=cfg,
                               policy=policy)
    assert np.isfinite(float(loss))


# -- paper models ----------------------------------------------------------

@pytest.mark.parametrize("name", ["vit-b", "gpt3-xl", "gpt-j"])
def test_paper_model_smoke(name):
    cfg = PAPER_MODELS[name].reduced()
    if cfg.family == "vit":
        params = vit.init_vit(jax.random.key(0), cfg, jnp.float32)
        patches = jnp.asarray(
            np.random.default_rng(0).standard_normal(
                (2, cfg.image_seq - 1, vit.PATCH_DIM)), jnp.float32)
        labels = jnp.array([1, 2], jnp.int32)
        loss, metrics = vit.vit_loss(params, patches, labels, cfg=cfg,
                                     policy=FP32)
        assert np.isfinite(float(loss))
    else:
        params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
        batch = frontends.make_batch(cfg, "train", 2, 32)
        loss, _ = lm.forward_train(params, batch, plan=UNSHARDED, cfg=cfg,
                                   policy=FP32)
        assert np.isfinite(float(loss))
