"""Goodput engine: loadgen determinism + seed independence, task SLO
validation, the percentiles helper, DeadlinePolicy ordering/shed/degrade
properties, and the overlapped host loop's token-identity guarantee.

Trace and policy properties are pure host-side logic (no model); the
end-to-end checks run the reduced phi4 config on one device like
tests/test_scheduler.py.  The load-bearing invariant throughout: nothing
in this subsystem — overlap, degrade, scheduling order, traffic seed —
may ever change a request's sampled tokens.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.precision import FP32
from repro.models import lm
from repro.serving import (ArrivalSpec, ChunkedPrefillPolicy, DeadlinePolicy,
                           EncodeTask, FCFSPolicy, InferenceEngine, LoadSpec,
                           PromptSpec, Request, SamplingParams, SLOSpec,
                           SpecConfig, arrival_times, make_policy,
                           make_trace, percentile, percentiles, replay)
from repro.serving.tasks import GenerateTask, validate_task


# --------------------------------------------------------------------------
# load generator (no model)
# --------------------------------------------------------------------------

def _spec(n=2000, **kw):
    kw.setdefault("prompts", PromptSpec(min_len=8, max_len=64,
                                        tail_alpha=1.5, shared_frac=0.3,
                                        prefix_len=8, encode_frac=0.2,
                                        sampled_frac=0.5))
    kw.setdefault("slo", SLOSpec(ttft_ms=250.0, tpot_ms=50.0))
    return LoadSpec(requests=n, vocab=1000, **kw)


def _fingerprint(trace):
    return [(tt.t_s, tt.task.uid, type(tt.task).__name__,
             len(tt.task.prompt), int(tt.task.prompt[0]),
             int(tt.task.prompt[-1])) for tt in trace]


def test_trace_deterministic_at_scale():
    """Same (spec, seeds, uid0) => identical trace, across thousands of
    requests mixing encode/generate, shared prefixes, and a long tail."""
    a = make_trace(_spec(), arrival_seed=7, prompt_seed=3, uid0=100)
    b = make_trace(_spec(), arrival_seed=7, prompt_seed=3, uid0=100)
    assert _fingerprint(a) == _fingerprint(b)
    assert len(a) == 2000
    assert [tt.task.uid for tt in a] == list(range(100, 2100))
    assert all(x.t_s <= y.t_s for x, y in zip(a, a[1:]))
    # the blend actually happened
    kinds = {type(tt.task).__name__ for tt in a}
    assert kinds == {"EncodeTask", "GenerateTask"}
    lens = [len(tt.task.prompt) for tt in a]
    assert min(lens) >= 8 and max(lens) == 64      # Pareto tail hits cap
    # every task carries the SLO
    assert all(tt.task.deadline_ms == 250.0 for tt in a)
    gens = [tt.task for tt in a if isinstance(tt.task, GenerateTask)]
    assert all(t.slo_tpot_ms == 50.0 for t in gens)


def test_arrival_seed_never_touches_request_content():
    """Changing the traffic seed reshuffles WHEN requests arrive, never
    what any request computes: prompts, task classes, and sampling seeds
    are identical per uid; only the clock moves."""
    a = make_trace(_spec(200), arrival_seed=0, prompt_seed=5)
    b = make_trace(_spec(200), arrival_seed=99, prompt_seed=5)
    assert [tt.t_s for tt in a] != [tt.t_s for tt in b]
    for x, y in zip(a, b):
        assert type(x.task) is type(y.task)
        np.testing.assert_array_equal(x.task.prompt, y.task.prompt)
        if isinstance(x.task, GenerateTask):
            assert x.task.sampling == y.task.sampling
            if x.task.sampling.temperature > 0:
                # per-request sampling is keyed by uid, not traffic seed
                assert x.task.sampling.seed == x.task.uid


def test_prompt_seed_never_touches_arrival_clock():
    a = make_trace(_spec(200), arrival_seed=5, prompt_seed=0)
    b = make_trace(_spec(200), arrival_seed=5, prompt_seed=99)
    assert [tt.t_s for tt in a] == [tt.t_s for tt in b]
    assert any(len(x.task.prompt) != len(y.task.prompt)
               or not np.array_equal(x.task.prompt, y.task.prompt)
               for x, y in zip(a, b))


def test_shared_prefix_requests_share_tokens():
    trace = make_trace(_spec(300), prompt_seed=1)
    tasks = [tt.task for tt in trace]
    heads = {tuple(t.prompt[:8].tolist()) for t in tasks}
    # one head is the shared prefix, carried by ~30% of the trace
    counts = sorted((sum(1 for t in tasks
                         if tuple(t.prompt[:8].tolist()) == h) for h in heads),
                    reverse=True)
    assert counts[0] > 50


def test_bursty_arrivals_deterministic_and_bounded():
    spec = ArrivalSpec(kind="bursty", rate_rps=5.0, dwell_s=0.5)
    rng = np.random.default_rng(4)
    t1 = arrival_times(spec, 500, np.random.default_rng(4))
    t2 = arrival_times(spec, 500, rng)
    np.testing.assert_array_equal(t1, t2)
    assert np.all(np.diff(t1) >= 0)
    mean_rate = 500 / t1[-1]
    assert spec.rate_rps < mean_rate < spec.hi_rate   # MMPP mixes lo/hi


def test_loadgen_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        ArrivalSpec(kind="lumpy")
    with pytest.raises(ValueError, match="rate_rps"):
        ArrivalSpec(rate_rps=0.0)
    with pytest.raises(ValueError, match="min_len"):
        PromptSpec(min_len=0)
    with pytest.raises(ValueError, match="prefix_len"):
        PromptSpec(min_len=4, shared_frac=0.5, prefix_len=0)
    with pytest.raises(ValueError, match="sampled_frac"):
        PromptSpec(sampled_frac=1.5)
    with pytest.raises(ValueError, match="requests"):
        LoadSpec(requests=0, vocab=100)


# --------------------------------------------------------------------------
# task SLO validation (satellite: construction AND submit)
# --------------------------------------------------------------------------

def _task(**kw):
    return GenerateTask(uid=0, prompt=np.zeros((4,), np.int32), **kw)


def test_task_validation_at_construction():
    for bad in (0.0, -5.0, math.nan, math.inf):
        with pytest.raises(ValueError, match="deadline_ms"):
            _task(deadline_ms=bad)
        with pytest.raises(ValueError, match="slo_tpot_ms"):
            _task(slo_tpot_ms=bad)
    with pytest.raises(ValueError, match="priority"):
        _task(priority=math.nan)
    with pytest.raises(ValueError, match="priority"):
        _task(priority="urgent")
    with pytest.raises(ValueError, match="deadline_ms"):
        EncodeTask(uid=0, prompt=np.zeros((4,), np.int32), deadline_ms=-1.0)
    # valid combinations construct fine
    t = _task(deadline_ms=100.0, slo_tpot_ms=20.0, priority=2)
    validate_task(t)
    assert t.slack_ms(t._t_submit) == 100.0
    assert _task().slack_ms() == math.inf


def test_submit_revalidates_mutated_task():
    """Construction validates, but tasks are mutable — Engine.submit must
    re-check so a corrupted deadline cannot enter the queue."""
    cfg, params = _phi4()
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32)
    t = _task(deadline_ms=100.0)
    t.deadline_ms = -1.0
    with pytest.raises(ValueError, match="deadline_ms"):
        engine.submit(t)
    t2 = _task()
    t2.priority = math.nan
    with pytest.raises(ValueError, match="priority"):
        engine.submit(t2)


# --------------------------------------------------------------------------
# percentiles helper (satellite: one implementation, everywhere)
# --------------------------------------------------------------------------

def test_percentiles_matches_percentile_and_adds_p99():
    vals = list(np.random.default_rng(0).uniform(0, 100, 173))
    out = percentiles(vals)
    assert set(out) == {"p50", "p95", "p99"}
    for q in (50, 95, 99):
        assert out[f"p{q}"] == percentile(vals, q)
    assert out["p50"] <= out["p95"] <= out["p99"]
    assert percentiles([]) == {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    assert percentiles([7.0], qs=(10, 90)) == {"p10": 7.0, "p90": 7.0}


# --------------------------------------------------------------------------
# DeadlinePolicy properties (no model)
# --------------------------------------------------------------------------

def _dtasks(specs, now):
    """specs: (uid, deadline_ms or None, age_s)."""
    out = []
    for uid, dl, age in specs:
        t = GenerateTask(uid=uid, prompt=np.zeros((4,), np.int32),
                         deadline_ms=dl)
        t._t_submit = now - age
        t._seq = uid
        out.append(t)
    return out


def test_deadline_order_is_ascending_slack_stable():
    now = 1000.0
    q = _dtasks([(0, None, 0.0), (1, 500.0, 0.1), (2, 50.0, 0.0),
                 (3, None, 9.0), (4, 500.0, 0.4)], now)
    order = DeadlinePolicy().admission_order(q, now)
    # tightest slack first (uid4: 100ms, uid2: 50ms... uid2=50, uid4=100,
    # uid1=400), no-deadline tasks keep arrival order at the back
    assert [t.uid for t in order] == [2, 4, 1, 0, 3]


def test_deadline_victim_is_most_slack():
    now = 1000.0
    running = _dtasks([(0, 50.0, 0.0), (1, None, 1.0), (2, 900.0, 0.0)],
                      now)
    assert DeadlinePolicy().select_victim(running, now).uid == 1


def test_shed_candidates_only_provably_expired():
    now = 1000.0
    q = _dtasks([(0, 100.0, 0.05),      # 50ms slack left: keep
                 (1, 100.0, 0.2),       # expired 100ms ago: shed
                 (2, None, 99.0),       # no deadline: never shed
                 (3, 100.0, 0.5)], now)  # expired but has a token: keep
    q[3].output.append(42)
    shed = DeadlinePolicy().shed_candidates(q, now)
    assert [t.uid for t in shed] == [1]
    assert DeadlinePolicy(shed=False).shed_candidates(q, now) == []
    # a measured TTFT floor sheds earlier: 50ms slack < 60ms floor
    early = DeadlinePolicy(ttft_floor_ms=60.0).shed_candidates(q, now)
    assert [t.uid for t in early] == [0, 1]


def test_degrade_level_and_chunk_budget():
    pol = DeadlinePolicy(chunk_tokens=32, degrade_depth=2.0)
    assert pol.degrade_level(n_queued=4, n_slots=2) == 0
    assert pol.degrade_level(n_queued=5, n_slots=2) == 1
    assert pol.effective_chunk_tokens(0) == 32
    assert pol.effective_chunk_tokens(1) == 16
    assert DeadlinePolicy(chunk_tokens=12).effective_chunk_tokens(1) == 8
    assert DeadlinePolicy().effective_chunk_tokens(1) is None
    assert make_policy("deadline", chunk_tokens=24).chunk_tokens == 24


# --------------------------------------------------------------------------
# end-to-end: overlap identity, shed, degrade, SLO accounting
# --------------------------------------------------------------------------

_CACHE = {}


def _phi4():
    if "phi4" not in _CACHE:
        cfg = get_config("phi4-mini-3.8b").reduced()
        params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
        _CACHE["phi4"] = (cfg, params)
    return _CACHE["phi4"]


def _reqs(cfg, lens, *, max_new=6, uid0=0, **kw):
    rng = np.random.default_rng(31)
    reqs = []
    for i, n in enumerate(lens):
        uid = uid0 + i
        reqs.append(Request(
            uid=uid, prompt=rng.integers(0, cfg.vocab, n, dtype=np.int32),
            max_new_tokens=max_new,
            sampling=SamplingParams(temperature=0.8, top_k=20, seed=uid)
            if uid % 2 else SamplingParams(), **kw))
    return reqs


def _run(cfg, params, reqs, **kw):
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32, **kw)
    for r in reqs:
        engine.submit(r)
    done = engine.run()
    return engine, {t.uid: list(t.output) for t in done}


def test_overlap_token_identical_to_sync():
    """The overlapped loop dispatches step N+1 before fetching step N's
    tokens; greedy and sampled outputs must be byte-identical to the
    synchronous loop, and the fast path must actually engage."""
    cfg, params = _phi4()
    lens = [5, 11, 20, 9, 14, 6]
    _, sync = _run(cfg, params, _reqs(cfg, lens))
    eng, ovl = _run(cfg, params, _reqs(cfg, lens), overlap=True)
    assert ovl == sync
    st = eng.stats()
    assert st.overlapped_steps > 0
    assert st.to_dict()["host_overlap_ratio"] > 0


def test_overlap_token_identical_chunked():
    cfg, params = _phi4()
    lens = [25, 11, 40, 9, 33, 6]
    _, sync = _run(cfg, params, _reqs(cfg, lens),
                   scheduler=ChunkedPrefillPolicy(16))
    eng, ovl = _run(cfg, params, _reqs(cfg, lens),
                    scheduler=ChunkedPrefillPolicy(16), overlap=True)
    assert ovl == sync
    assert eng.stats().overlapped_steps > 0


def test_overlap_token_identical_prefix_cache_warm():
    """Warm prefix-cache traffic through the overlapped loop: the fast
    path must respect shared block refcounts (a COW hazard if it wrote
    the next token into a block another request still reads)."""
    cfg, params = _phi4()
    rng = np.random.default_rng(9)
    prefix = rng.integers(0, cfg.vocab, 18, dtype=np.int32)

    def waves(overlap):
        engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                                 policy=FP32, prefix_cache=True,
                                 kv_pool_blocks=16, overlap=overlap)
        out = {}
        for uid0 in (0, 100):
            for i in range(4):
                tail = np.full((3 + i,), (7 * i + 3) % cfg.vocab, np.int32)
                engine.submit(Request(
                    uid=uid0 + i, prompt=np.concatenate([prefix, tail]),
                    max_new_tokens=5,
                    sampling=SamplingParams(temperature=0.8, top_k=20,
                                            seed=i)
                    if i % 2 else SamplingParams()))
            for t in engine.run():
                out[t.uid] = list(t.output)
        return engine, out

    _, sync = waves(False)
    eng, ovl = waves(True)
    assert ovl == sync
    assert eng.stats().prefix_cache_hit_rate > 0
    assert eng.stats().overlapped_steps > 0


def test_overlap_with_spec_falls_back_and_matches():
    """Speculation commits multiple tokens per step — the single-token
    fast path must stand down, and outputs must still match the sync
    spec run exactly."""
    cfg, params = _phi4()
    lens = [5, 11, 9, 6]
    spec = SpecConfig(draft="self", k=3)
    _, sync = _run(cfg, params, _reqs(cfg, lens), spec=spec)
    eng, ovl = _run(cfg, params, _reqs(cfg, lens), spec=spec, overlap=True)
    assert ovl == sync
    assert eng.stats().spec_rounds > 0
    assert eng.stats().overlapped_steps == 0


def test_shed_is_typed_and_counted():
    """An expired deadline sheds with a typed Rejection instead of being
    served to a guaranteed miss; healthy traffic completes untouched."""
    cfg, params = _phi4()
    doomed = _reqs(cfg, [8, 12], uid0=0, deadline_ms=0.001)
    healthy = _reqs(cfg, [8, 12], uid0=50, deadline_ms=600_000.0)
    eng, out = _run(cfg, params, doomed + healthy,
                    scheduler=DeadlinePolicy())
    assert {t.uid for t in eng.shed} == {0, 1}
    for t in eng.shed:
        assert t.rejection.kind == "slo_unattainable"
        assert "deadline_ms" in t.rejection.detail
        assert t.output == [] and t.done
        assert t.uid in out                # shed tasks reach completed too
    assert all(len(out[u]) == 6 for u in (50, 51))
    st = eng.stats()
    assert st.requests_shed == 2
    assert st.slo_met == 2 and st.slo_requests == 4
    assert st.slo_attainment == 0.5


def test_degraded_spec_is_lossless():
    """Degrade disables speculation for admitted requests — tokens must
    not change (speculation is exact), only the proposal count."""
    cfg, params = _phi4()
    lens = [5, 11, 9, 6, 13, 7]
    spec = SpecConfig(draft="self", k=3)
    _, base = _run(cfg, params, _reqs(cfg, lens), spec=spec)
    # degrade_depth=0: any queue depth > 0 trips level 1 immediately
    eng, deg = _run(cfg, params, _reqs(cfg, lens), spec=spec,
                    scheduler=DeadlinePolicy(degrade_depth=0.0))
    assert deg == base
    st = eng.stats()
    assert st.requests_degraded == len(lens)
    assert st.spec_proposed_tokens == 0


def test_slo_accounting_and_stats_surface():
    cfg, params = _phi4()
    reqs = _reqs(cfg, [5, 9, 14], deadline_ms=600_000.0,
                 slo_tpot_ms=60_000.0)
    eng, _ = _run(cfg, params, reqs, scheduler=DeadlinePolicy())
    st = eng.stats()
    assert st.slo_requests == 3 and st.slo_attainment == 1.0
    d = st.to_dict()
    for key in ("slo_attainment", "ttft_p99_ms", "ttft_slo_ratio_p50",
                "ttft_slo_ratio_p99", "tpot_p50_ms", "tpot_p99_ms",
                "requests_shed", "requests_degraded", "overlapped_steps",
                "host_overlap_ratio"):
        assert key in d, key
    assert "SLO" in st.summary()
    for t in eng.completed:
        assert t.latency_ms > 0 and t.tpot_ms > 0


def test_replay_open_loop_end_to_end():
    """A paced Poisson trace through the overlapped deadline engine: the
    full loadgen -> replay -> stats path used by the goodput bench."""
    cfg, params = _phi4()
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32, scheduler=DeadlinePolicy(),
                             overlap=True)
    spec = LoadSpec(requests=8, vocab=cfg.vocab,
                    arrival=ArrivalSpec(rate_rps=50.0),
                    prompts=PromptSpec(min_len=4, max_len=16),
                    slo=SLOSpec(ttft_ms=600_000.0), max_new=4)
    replay(engine, make_trace(spec, uid0=1000), time_scale=0)  # compile
    engine.reset_stats()
    done, wall = replay(engine, make_trace(spec))
    assert len(done) == 8 and wall > 0
    assert all(t.done for t in done)
    st = engine.stats()
    assert st.slo_requests == 8 and st.slo_attainment == 1.0
