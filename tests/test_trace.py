"""Observability layer: request-level tracing + MFU/MBU attribution.

Covers serving/trace.py end to end through the engine: span nesting and
ordering under the overlapped host loop, ring-buffer eviction, Chrome
trace-event schema validity (Perfetto-loadable), token identity with
tracing on vs off across chunked / prefix-warm / speculative traffic,
TTFT reconstruction from the trace alone, the per-phase MFU/MBU
derivation (stats.phase_util vs trace.derive_phase_metrics agreeing),
the Reservoir sampling satellite, and the Prometheus text snapshot.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.precision import FP32
from repro.models import lm
from repro.serving import (ChunkedPrefillPolicy, DeadlinePolicy,
                           InferenceEngine, Request, Reservoir,
                           SamplingParams, SpecConfig, Tracer,
                           derive_phase_metrics, make_policy, percentile,
                           prometheus_text, spec_support_reason,
                           validate_chrome_trace)
from repro.serving.stats import EngineStats
from repro.serving.trace import PID_ENGINE, PID_REQUEST


@pytest.fixture(scope="module")
def model():
    cfg = get_config("phi4-mini-3.8b").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    return cfg, params


def _prompts(cfg, lengths, seed=11):
    """lengths entries may be ints (drawn here) or ready-made prompts."""
    rng = np.random.default_rng(seed)
    return [n if isinstance(n, np.ndarray)
            else rng.integers(0, cfg.vocab, n, dtype=np.int32)
            for n in lengths]


def _run(cfg, params, *, tracer=None, overlap=False, scheduler=None,
         prefix_cache=False, spec=None, lengths=(6, 13, 20), max_new=4,
         uid0=0, deadline_ms=0.0):
    engine = InferenceEngine(
        cfg, params, batch_size=2, max_seq=64, policy=FP32,
        overlap=overlap, scheduler=scheduler, prefix_cache=prefix_cache,
        spec=spec, tracer=tracer)
    for i, p in enumerate(_prompts(cfg, lengths)):
        engine.submit(Request(
            uid=uid0 + i, prompt=p, max_new_tokens=max_new,
            deadline_ms=deadline_ms or None,
            sampling=SamplingParams(temperature=0.8, top_k=20, seed=i)
            if i % 2 else SamplingParams()))
    done = engine.run()
    return engine, {r.uid - uid0: list(r.output) for r in done}


@pytest.fixture(scope="module")
def traced_run(model):
    """One shared overlapped traced run, reused by the schema / ordering /
    reconstruction tests (compilation dominates, so share it)."""
    cfg, params = model
    tracer = Tracer()
    engine, out = _run(cfg, params, tracer=tracer, overlap=True,
                       scheduler=DeadlinePolicy(), deadline_ms=60_000.0)
    return tracer, engine, out


def test_disabled_tracer_is_noop(model):
    cfg, params = model
    tracer = Tracer(enabled=False)
    assert not tracer
    _, traced = _run(cfg, params, tracer=tracer)
    _, plain = _run(cfg, params, tracer=None)
    assert len(tracer.events) == 0
    assert traced == plain


def test_ring_buffer_evicts_oldest():
    tracer = Tracer(capacity=4)
    for i in range(10):
        tracer.instant(f"e{i}", float(i), tid=0)
    assert len(tracer) == 4
    assert tracer.dropped == 6
    assert [e["name"] for e in tracer.events] == ["e6", "e7", "e8", "e9"]
    doc = tracer.chrome_trace()
    assert doc["otherData"]["dropped_events"] == 6


def test_chrome_trace_schema(traced_run, tmp_path):
    tracer, _, _ = traced_run
    assert len(tracer.events) > 0
    doc = tracer.chrome_trace()
    assert validate_chrome_trace(doc) == []
    # survives a JSON round trip (what Perfetto actually loads)
    path = tmp_path / "trace.json"
    n = tracer.write(str(path))
    assert n == len(tracer.events)
    loaded = json.loads(path.read_text())
    assert validate_chrome_trace(loaded) == []
    for ev in loaded["traceEvents"]:
        if ev["ph"] == "X":
            assert ev["dur"] >= 0


def test_span_ordering_and_nesting(traced_run):
    """Exported timestamps are monotonic even under the overlapped loop,
    and every retired request's lifecycle instants sit inside its
    request span on the request's own track."""
    tracer, engine, out = traced_run
    doc = tracer.chrome_trace()
    ts = [e["ts"] for e in doc["traceEvents"] if e["ph"] != "M"]
    assert ts == sorted(ts)
    spans = {}          # uid -> request span
    firsts = {}         # uid -> first_token instant
    for e in doc["traceEvents"]:
        if e.get("cat") == "request" and e["name"] == "request":
            spans[e["tid"]] = e
        if e["name"] == "first_token":
            firsts[e["tid"]] = e
    assert set(spans) == set(out)
    for uid, span in spans.items():
        assert span["pid"] == PID_REQUEST
        assert uid in firsts
        assert span["ts"] <= firsts[uid]["ts"] <= span["ts"] + span["dur"]
    # engine rows exist too: step spans on the engine pid
    steps = [e for e in doc["traceEvents"] if e.get("cat") == "step"]
    assert steps and all(e["pid"] == PID_ENGINE for e in steps)
    assert {"prefill", "decode_step", "engine_step"} <= {
        e["name"] for e in steps}
    assert any(e["name"] == "decode_dispatch" for e in steps)


def test_ttft_reconstruction(traced_run):
    """TTFT recomputed from the trace alone (first_token instant minus
    request-span start) must match the value the span carries."""
    tracer, _, _ = traced_run
    doc = tracer.chrome_trace()
    spans = {e["tid"]: e for e in doc["traceEvents"]
             if e.get("cat") == "request" and e["name"] == "request"}
    firsts = {e["tid"]: e for e in doc["traceEvents"]
              if e["name"] == "first_token"}
    assert spans
    for uid, span in spans.items():
        ttft_ms = (firsts[uid]["ts"] - span["ts"]) / 1e3
        assert ttft_ms == pytest.approx(span["args"]["ttft_ms"], abs=0.1)


@pytest.mark.parametrize("mode", ["chunked", "warm_prefix", "spec"])
def test_token_identity_traced_vs_untraced(model, mode):
    """Tracing is a pure observer: identical committed tokens with the
    tracer attached, across the hook-heavy paths (chunked prefill,
    prefix-cache warm admission, speculative decode)."""
    cfg, params = model
    kw = {}
    if mode == "chunked":
        kw = dict(scheduler=ChunkedPrefillPolicy(8), lengths=(30, 6, 25))
    elif mode == "warm_prefix":
        rng = np.random.default_rng(4)
        shared = rng.integers(0, cfg.vocab, 24, dtype=np.int32)
        lengths = tuple([np.concatenate([shared, t]) for t in
                         _prompts(cfg, (4, 6, 5), seed=5)])
        kw = dict(prefix_cache=True, lengths=lengths,
                  scheduler=make_policy("fcfs", cache_aware=True))
    elif mode == "spec":
        if spec_support_reason(cfg) is not None:
            pytest.skip(spec_support_reason(cfg))
        kw = dict(spec=SpecConfig(draft="self", k=2))
    tracer = Tracer()
    _, traced = _run(cfg, params, tracer=tracer, **kw)
    _, plain = _run(cfg, params, tracer=None, **kw)
    assert traced == plain
    assert len(tracer.events) > 0
    assert validate_chrome_trace(tracer.chrome_trace()) == []


def test_warm_prefix_emits_warm_hit(model):
    """Second pass over a shared prefix emits warm_hit instants and the
    prefill_chunk/prefill spans mark recompute vs first admission."""
    cfg, params = model
    rng = np.random.default_rng(4)
    shared = rng.integers(0, cfg.vocab, 24, dtype=np.int32)
    tails = _prompts(cfg, (4, 6), seed=5)
    tracer = Tracer()
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32, prefix_cache=True,
                             scheduler=make_policy("fcfs", cache_aware=True),
                             tracer=tracer)
    uid = 0
    for round_ in range(2):
        for t in tails:
            engine.submit(Request(uid=uid, max_new_tokens=3,
                                  prompt=np.concatenate([shared, t])))
            uid += 1
        engine.run()
    names = [e["name"] for e in tracer.events]
    assert "warm_hit" in names
    hit = next(e for e in tracer.events if e["name"] == "warm_hit")
    assert hit["args"]["cached_prefix"] > 0


def test_spec_trace_annotations(model):
    cfg, params = model
    if spec_support_reason(cfg) is not None:
        pytest.skip(spec_support_reason(cfg))
    tracer = Tracer()
    _run(cfg, params, tracer=tracer, spec=SpecConfig(draft="self", k=2))
    verifies = [e for e in tracer.events if e["name"] == "spec_verify"]
    drafts = [e for e in tracer.events if e["name"] == "spec_draft"]
    assert verifies and drafts
    for v in verifies:
        a = v["args"]
        assert a["phase"] == "verify"
        assert a["proposed"] >= a["accepted"] >= 0
        assert 0.0 <= a["accept_rate"] <= 1.0


def test_phase_util_and_trace_derivation_agree(traced_run):
    """stats.phase_util() (the counters) and derive_phase_metrics (the
    trace) are two routes to the same per-phase MFU/MBU numbers."""
    _, engine, _ = traced_run
    tracer = engine.tracer
    st = engine.stats()
    pu = st.phase_util()
    assert "prefill" in pu and "decode" in pu
    for row in pu.values():
        assert row["mfu"] > 0 and row["mbu"] > 0
        assert row["time_s"] > 0
    derived = derive_phase_metrics(
        tracer.events,
        flops_per_token=st.model_flops_per_token,
        weight_bytes=st.weight_bytes_per_device,
        kv_bytes_per_token=st.kv_bytes_per_token)
    for phase in ("prefill", "decode"):
        assert phase in derived
        for key in ("time_s", "tokens", "flops", "mfu", "mbu"):
            assert derived[phase][key] == pytest.approx(
                pu[phase][key], rel=1e-6), (phase, key)
    d = st.to_dict()
    assert d["phase_util"] == pu
    assert d["model_flops_per_token"] > 0
    assert d["kv_bytes_per_token"] > 0


def test_spec_engine_attributes_verify_phase(model):
    cfg, params = model
    if spec_support_reason(cfg) is not None:
        pytest.skip(spec_support_reason(cfg))
    engine, _ = _run(cfg, params, spec=SpecConfig(draft="self", k=2))
    pu = engine.stats().phase_util()
    assert "verify" in pu and "decode" not in pu
    assert pu["verify"]["mfu"] > 0


def test_reservoir_keeps_late_outliers():
    """The old sliding window dropped early history; a reservoir keeps
    every sample equally likely, so late outliers reach p99 AND early
    samples survive a long tail of later ones."""
    r = Reservoir(capacity=64, seed=0)
    for _ in range(64):
        r.add(1.0)
    for _ in range(10_000):
        r.add(1000.0)
    assert len(r) == 64 and r.seen == 10_064
    assert percentile(r, 99) == 1000.0
    # early samples are not certainly evicted (the window would keep 0)
    # with capacity/seen ≈ 0.6% each over 64 slots this holds w.h.p. for
    # the fixed seed; determinism is asserted below so it cannot flake
    r2 = Reservoir(capacity=64, seed=0)
    for _ in range(64):
        r2.add(1.0)
    for _ in range(10_000):
        r2.add(1000.0)
    assert list(r) == list(r2)


def test_stats_sample_fields_are_reservoirs():
    st = EngineStats()
    assert isinstance(st.ttft_ms, Reservoir)
    assert isinstance(st.queue_wait_ms, Reservoir)
    d = st.to_dict()
    for key in ("ttft_p99_ms", "queue_wait_p99_ms", "decode_step_p99_ms",
                "decode_stall_p99_ms", "encode_latency_p99_ms",
                "draft_time_ms_p99", "spec_path_depth_p99"):
        assert key in d, key


def test_prometheus_text_snapshot(traced_run):
    _, engine, _ = traced_run
    text = prometheus_text(engine.stats().to_dict())
    assert "serving_ar_tok_s" in text
    assert 'serving_phase_mfu{phase="decode"}' in text
    for line in text.splitlines():
        if line and not line.startswith("#"):
            name, val = line.rsplit(" ", 1)
            float(val)          # every sample parses
