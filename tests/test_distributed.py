"""Multi-device equivalence suite (runs _distributed_prog.py in a
subprocess so the forced 8-device XLA config never leaks into other
tests)."""
import os
import subprocess
import sys

import pytest

PROG = os.path.join(os.path.dirname(__file__), "_distributed_prog.py")


@pytest.mark.timeout(1200)
def test_distributed_equivalence():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, PROG], capture_output=True,
                       text=True, timeout=1100, env=env)
    sys.stdout.write(p.stdout)
    sys.stderr.write(p.stderr[-3000:])
    assert p.returncode == 0, "distributed program failed"
    assert "ALL OK" in p.stdout
