"""Block-paged KV cache: allocator invariants, paged-vs-dense decode
attention parity (jnp reference and Pallas interpret mode), batched prefill
admission parity, and out-of-blocks preemption correctness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.precision import FP32
from repro.kernels import ops, ref
from repro.kernels.flash_decode import paged_decode_attention
from repro.models import lm
from repro.serving import InferenceEngine, Request, SamplingParams
from repro.serving.kv_cache import BlockAllocator
from repro.sharding.plan import UNSHARDED


# --------------------------------------------------------------------------
# BlockAllocator
# --------------------------------------------------------------------------

def test_allocator_alloc_free_reuse():
    a = BlockAllocator(num_blocks=6, block_size=4)
    assert a.num_free == 6 and a.num_used == 0
    x = a.alloc(2)
    y = a.alloc(3)
    assert len(x) == 2 and len(y) == 3
    assert len(set(x) | set(y)) == 5          # distinct blocks
    assert a.num_used == 5 and a.peak_used == 5
    a.free(x)
    assert a.num_free == 3
    z = a.alloc(3)                            # freed blocks come back
    assert z is not None and a.num_free == 0
    a.free(y)
    a.free(z)
    assert a.num_free == 6 and a.peak_used == 6      # peak never dropped


def test_allocator_all_or_nothing_exhaustion():
    a = BlockAllocator(num_blocks=4, block_size=8)
    got = a.alloc(3)
    assert got is not None
    assert a.alloc(2) is None                 # only 1 free: no partial grant
    assert a.num_free == 1                    # failed alloc takes nothing
    assert a.alloc(1) is not None
    assert a.alloc(1) is None


def test_allocator_double_free_rejected():
    # RuntimeError, not assert: the guard must survive `python -O`
    a = BlockAllocator(num_blocks=3, block_size=2)
    x = a.alloc(2)
    a.free(x)
    with pytest.raises(RuntimeError, match="double free"):
        a.free(x)
    with pytest.raises(RuntimeError, match="within batch"):
        a.free([a.alloc(1)[0]] * 2)


def test_allocator_blocks_for():
    a = BlockAllocator(num_blocks=8, block_size=16)
    assert a.blocks_for(1) == 1
    assert a.blocks_for(16) == 1
    assert a.blocks_for(17) == 2


# --------------------------------------------------------------------------
# paged decode attention vs the dense oracle
# --------------------------------------------------------------------------

def _paged_from_dense(dense_k, dense_v, lengths, *, num_blocks, block_size,
                      seed=0):
    """Scatter a dense [B, S, KV, D] cache into a shuffled block pool +
    per-slot tables (absent entries -1)."""
    rng = np.random.default_rng(seed)
    B, S, KV, D = dense_k.shape
    MB = -(-S // block_size)
    k_pool = np.zeros((num_blocks, block_size, KV, D), dense_k.dtype)
    v_pool = np.zeros_like(k_pool)
    tables = np.full((B, MB), -1, np.int32)
    free = list(rng.permutation(num_blocks))
    for b in range(B):
        for e in range(-(-int(lengths[b]) // block_size)):
            blk = int(free.pop())
            tables[b, e] = blk
            sl = dense_k[b, e * block_size:(e + 1) * block_size]
            k_pool[blk, :len(sl)] = sl
            sl = dense_v[b, e * block_size:(e + 1) * block_size]
            v_pool[blk, :len(sl)] = sl
    return k_pool, v_pool, tables


@pytest.mark.parametrize("B,H,KV,D,BS,lengths", [
    (3, 4, 2, 16, 8, (5, 33, 17)),            # GQA, ragged
    (2, 4, 4, 16, 16, (1, 31)),               # MHA, length-1 edge
    (1, 8, 2, 32, 8, (40,)),                  # exactly full blocks
])
def test_paged_decode_matches_dense_oracle(B, H, KV, D, BS, lengths):
    """Paged reference AND Pallas paged kernel (interpret mode) == dense
    decode_attention_ref for ragged per-slot lengths."""
    rng = np.random.default_rng(11)
    S = -(-max(lengths) // BS) * BS
    NB = B * (-(-S // BS)) + 2
    q = rng.standard_normal((B, H, D)).astype(np.float32)
    dk = rng.standard_normal((B, S, KV, D)).astype(np.float32)
    dv = rng.standard_normal((B, S, KV, D)).astype(np.float32)
    kp, vp, tab = _paged_from_dense(dk, dv, lengths, num_blocks=NB,
                                    block_size=BS)
    lengths = jnp.asarray(np.asarray(lengths, np.int32))

    want = ref.decode_attention_ref(jnp.asarray(q), jnp.asarray(dk),
                                    jnp.asarray(dv), lengths)
    got_ref = ref.paged_decode_attention_ref(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(tab),
        lengths)
    np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    got_kernel = paged_decode_attention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(tab),
        lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(got_kernel), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_partials_sharded_merge_matches_dense():
    """The multi-device path: each cache shard runs the paged *partials*
    kernel over its local pool slice (non-owned table entries masked to -1)
    and the T4 merge rule combines the shards — equal to the dense oracle,
    with the pool never gathered."""
    from repro.core.attention import merge_partials

    rng = np.random.default_rng(17)
    B, H, KV, D, BS = 2, 4, 2, 16, 8
    lengths = (11, 26)
    S, NB, shards = 32, 8, 2
    q = rng.standard_normal((B, H, D)).astype(np.float32)
    dk = rng.standard_normal((B, S, KV, D)).astype(np.float32)
    dv = rng.standard_normal((B, S, KV, D)).astype(np.float32)
    kp, vp, tab = _paged_from_dense(dk, dv, lengths, num_blocks=NB,
                                    block_size=BS)
    lengths = jnp.asarray(np.asarray(lengths, np.int32))
    want = ref.decode_attention_ref(jnp.asarray(q), jnp.asarray(dk),
                                    jnp.asarray(dv), lengths)

    nb_loc = NB // shards
    parts = []
    for s_i in range(shards):
        start = s_i * nb_loc
        loc = tab - start
        present = (tab >= 0) & (loc >= 0) & (loc < nb_loc)
        loc = np.where(present, loc, -1).astype(np.int32)
        parts.append(ref.paged_decode_partials_ref(
            jnp.asarray(q), jnp.asarray(kp[start:start + nb_loc]),
            jnp.asarray(vp[start:start + nb_loc]), jnp.asarray(loc),
            lengths))
    # numpy mirror of the cross-device pmax/psum merge
    m_all = jnp.maximum(parts[0][1], parts[1][1])
    l_all = sum(l * jnp.exp(m - m_all) for _, m, l in parts)
    o_all = sum(o * jnp.exp(m - m_all)[..., None] for o, m, _ in parts)
    got = o_all / jnp.maximum(l_all, 1e-30)[..., None]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # single-shard partials + axis-free merge == the normalized kernel
    o, m, l = ref.paged_decode_partials_ref(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(tab),
        lengths)
    one = merge_partials(o, m, l, ())
    np.testing.assert_allclose(np.asarray(one), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_ops_dispatch_interpret():
    """ops.paged_decode_attention routes to the Pallas kernel under
    kernel_mode("interpret") and to the jnp oracle under "ref" — same
    numbers either way."""
    rng = np.random.default_rng(13)
    B, H, KV, D, BS = 2, 4, 2, 16, 8
    lengths = (9, 20)
    S, NB = 24, 8
    q = rng.standard_normal((B, H, D)).astype(np.float32)
    dk = rng.standard_normal((B, S, KV, D)).astype(np.float32)
    dv = rng.standard_normal((B, S, KV, D)).astype(np.float32)
    kp, vp, tab = _paged_from_dense(dk, dv, lengths, num_blocks=NB,
                                    block_size=BS)
    args = (jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(tab), jnp.asarray(np.asarray(lengths, np.int32)))
    with ops.kernel_mode("ref"):
        a = ops.paged_decode_attention(*args)
    with ops.kernel_mode("interpret"):
        b = ops.paged_decode_attention(*args)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)
    # the partials variant agrees between oracle and Pallas kernel too
    with ops.kernel_mode("ref"):
        ra = ops.paged_decode_partials(*args)
    with ops.kernel_mode("interpret"):
        rb = ops.paged_decode_partials(*args)
    for x, y in zip(ra, rb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# engine: batched prefill admission + preemption
# --------------------------------------------------------------------------

def _direct_tokens(cfg, params, prompt, n_new, max_seq=64):
    """Reference: unpadded batch-1 prefill + dense greedy decode loop."""
    batch = {"tokens": jnp.asarray(prompt)[None]}
    tok, caches, pos = lm.forward_prefill(params, batch, plan=UNSHARDED,
                                          cfg=cfg, policy=FP32,
                                          max_seq=max_seq)
    toks = [int(tok[0])]
    t, p = tok, pos
    for _ in range(n_new - 1):
        t, caches = lm.forward_decode(params, t, p, caches, plan=UNSHARDED,
                                      cfg=cfg, policy=FP32)
        p = p + 1
        toks.append(int(t[0]))
    return toks


def _phi4():
    cfg = get_config("phi4-mini-3.8b").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    return cfg, params


def test_batched_prefill_matches_sequential():
    """Four same-bucket prompts admitted as ONE batched prefill call produce
    exactly the tokens of four sequential unpadded prefill+decode runs."""
    cfg, params = _phi4()
    rng = np.random.default_rng(29)
    prompts = [rng.integers(0, cfg.vocab, 13, dtype=np.int32)
               for _ in range(4)]
    engine = InferenceEngine(cfg, params, batch_size=4, max_seq=64,
                             policy=FP32)
    for uid, p in enumerate(prompts):
        engine.submit(Request(uid=uid, prompt=p, max_new_tokens=4))
    done = sorted(engine.run(), key=lambda r: r.uid)
    assert len(done) == 4
    # all four shared one (bucket=16, group=4) compiled prefill
    assert engine.stats().prefill_compiles == 1
    for req in done:
        assert _direct_tokens(cfg, params, req.prompt, 4) == req.output


def test_engine_pool_sized_to_active_tokens():
    """Block accounting: peak pool usage covers live tokens, not
    B x max_seq, and every block is back in the free list after retire."""
    cfg, params = _phi4()
    rng = np.random.default_rng(31)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (6, 14)]
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32, block_size=8)
    for uid, p in enumerate(prompts):
        engine.submit(Request(uid=uid, prompt=p, max_new_tokens=4))
    engine.run()
    st = engine.stats()
    dense_blocks = engine.B * (64 // 8)
    assert st.kv_pool_blocks == dense_blocks          # default capacity
    # peak usage: ceil((6+4)/8) + ceil((14+4)/8) = 2 + 3 blocks
    assert st.peak_blocks_used <= 5 < dense_blocks
    assert st.blocks_per_token >= 1.0
    assert engine.allocator.num_free == engine.allocator.num_blocks
    assert (engine.block_tables == -1).all()          # no stale table rows


def test_out_of_blocks_preemption_recovers_exactly():
    """A pool too small for the full batch forces recompute preemption; the
    preempted request is re-admitted and its final output matches the
    uncontended reference, with no leaked blocks."""
    cfg, params = _phi4()
    rng = np.random.default_rng(37)
    prompts = [rng.integers(0, cfg.vocab, 16, dtype=np.int32)
               for _ in range(3)]
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32, block_size=8, kv_pool_blocks=5)
    for uid, p in enumerate(prompts):
        engine.submit(Request(uid=uid, prompt=p, max_new_tokens=12))
    done = sorted(engine.run(), key=lambda r: r.uid)
    st = engine.stats()
    assert len(done) == 3
    assert st.preemptions > 0 and st.recompute_tokens > 0
    assert st.recompute_time_s > 0          # overhead split out of NAR time
    for req in done:
        assert _direct_tokens(cfg, params, req.prompt, 12) == req.output
    assert engine.allocator.num_free == engine.allocator.num_blocks


def test_preemption_preserves_sampled_continuations():
    """Recompute preemption must also reproduce *sampled* sequences: the
    (seed, position)-keyed draws make the re-prefilled continuation land on
    the same tokens the uncontended engine produces."""
    cfg, params = _phi4()
    rng = np.random.default_rng(41)
    prompts = [rng.integers(0, cfg.vocab, 16, dtype=np.int32)
               for _ in range(3)]
    sampling = lambda uid: SamplingParams(temperature=1.0, seed=100 + uid)

    def run(**kw):
        engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                                 policy=FP32, **kw)
        for uid, p in enumerate(prompts):
            engine.submit(Request(uid=uid, prompt=p, max_new_tokens=10,
                                  sampling=sampling(uid)))
        return ({r.uid: r.output for r in engine.run()}, engine.stats())

    want, st_big = run()
    got, st_small = run(block_size=8, kv_pool_blocks=5)
    assert st_big.preemptions == 0
    assert st_small.preemptions > 0
    assert got == want


def test_pool_too_small_raises():
    """A single request that cannot ever fit the pool is a configuration
    error, not a hang."""
    cfg, params = _phi4()
    rng = np.random.default_rng(43)
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32, block_size=8, kv_pool_blocks=2)
    engine.submit(Request(uid=0, prompt=rng.integers(0, cfg.vocab, 30,
                                                     dtype=np.int32),
                          max_new_tokens=4))
    with pytest.raises(RuntimeError, match="KV pool too small"):
        engine.run()


def test_dense_fallback_engine_parity():
    """paged=False — the layout a batch-sharded (dp > 1) mesh falls back
    to — still serves exactly through the batched-admission row scatter and
    the tables-free decode step."""
    cfg, params = _phi4()
    rng = np.random.default_rng(53)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (7, 13, 13)]
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32, paged=False)
    assert engine.allocator is None and engine.layout is None
    for uid, p in enumerate(prompts):
        engine.submit(Request(uid=uid, prompt=p, max_new_tokens=4))
    done = sorted(engine.run(), key=lambda r: r.uid)
    assert len(done) == 3
    for req in done:
        assert _direct_tokens(cfg, params, req.prompt, 4) == req.output
    st = engine.stats()
    assert st.kv_pool_blocks == 0 and st.pool_utilization == 0.0


def test_window_arch_keeps_dense_ring_and_frees_blocks():
    """Sliding-window layers fall back to the dense ring cache while global
    layers page; retirement still returns every block."""
    cfg = get_config("gemma3-27b").reduced()
    assert cfg.sliding_window > 0
    params = lm.init_lm(jax.random.key(1), cfg, jnp.float32)
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32)
    # only the full-context segments are paged
    segs = engine.layout.segments
    kinds = [k for k, _ in cfg.schedule]
    assert segs == tuple(k == "attn" for k in kinds)
    rng = np.random.default_rng(47)
    for uid in range(3):
        engine.submit(Request(uid=uid,
                              prompt=rng.integers(0, cfg.vocab, 9,
                                                  dtype=np.int32),
                              max_new_tokens=3))
    done = sorted(engine.run(), key=lambda r: r.uid)
    for req in done:
        assert _direct_tokens(cfg, params, req.prompt, 3) == req.output
    assert engine.allocator.num_free == engine.allocator.num_blocks
