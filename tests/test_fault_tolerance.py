"""Fault tolerance: restart-with-restore, straggler watchdog, preemption."""
import os
import signal

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.runtime import PreemptionGuard, StragglerWatchdog
from repro.runtime.fault_tolerance import run_with_restarts


def test_run_with_restarts_recovers(tmp_path):
    """Inject failures; the loop must restore and finish with the exact
    same final state as a failure-free run (step-indexed determinism)."""
    def make_state():
        return {"acc": jnp.zeros(())}

    def step_fn(state, step):
        return {"acc": state["acc"] + step}

    failed = set()

    def fail_at(step):
        if step == 7 and 7 not in failed:
            failed.add(7)
            return True
        return False

    ck = Checkpointer(str(tmp_path / "a"), keep=10)
    final, executed, restarts = run_with_restarts(
        make_state, step_fn, ck, total_steps=20, checkpoint_every=5,
        fail_at=fail_at)
    assert restarts == 1
    assert float(final["acc"]) == sum(range(20))
    # some steps were re-executed after restore (5 and 6 re-run)
    assert executed > 20


def test_run_with_restarts_gives_up(tmp_path):
    ck = Checkpointer(str(tmp_path / "b"))
    try:
        run_with_restarts(lambda: {"x": jnp.zeros(())},
                          lambda s, i: s, ck, total_steps=5,
                          max_restarts=2, fail_at=lambda s: True)
        raised = False
    except RuntimeError:
        raised = True
    assert raised


def test_straggler_watchdog_flags_outliers():
    wd = StragglerWatchdog(k_sigma=3.0, min_ratio=1.5, warmup=3)
    flagged = []
    for step in range(20):
        dt = 0.10 if step != 15 else 0.50
        if wd.observe(step, dt):
            flagged.append(step)
    assert flagged == [15]
    assert wd.events[0]["step"] == 15
    # EMA must not be poisoned by the outlier
    assert abs(wd.mean - 0.10) < 0.01


def test_preemption_guard_catches_sigterm():
    with PreemptionGuard(signals=(signal.SIGUSR1,)) as guard:
        assert not guard.should_stop
        os.kill(os.getpid(), signal.SIGUSR1)
        assert guard.should_stop
