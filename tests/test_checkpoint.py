"""Checkpointing: roundtrip, atomic commit, async, gc, restore-into-struct."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def _state(seed=0):
    k = jax.random.key(seed)
    return {"step": jnp.asarray(3, jnp.int32),
            "params": {"w": jax.random.normal(k, (8, 16)),
                       "scale": jnp.ones((16,), jnp.bfloat16)},
            "nested": ({"m": jnp.zeros((8, 16))},)}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    s = _state()
    ck.save(s, 3)
    r = ck.restore(jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape,
                                                               x.dtype), s))
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_wait(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(_state(), 5)
    ck.wait()
    assert ck.latest_step() == 5


def test_gc_keeps_last(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        ck.save(_state(), step)
    assert ck.all_steps() == [3, 4]


def test_no_partial_commit(tmp_path):
    """A .tmp directory must never be listed as a checkpoint."""
    ck = Checkpointer(str(tmp_path))
    os.makedirs(tmp_path / "step_00000007.tmp")
    assert ck.latest_step() is None
    ck.save(_state(), 7)
    assert ck.latest_step() == 7


def test_restore_specific_step_and_manifest(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(0), 1, meta={"arch": "phi4"})
    ck.save(_state(1), 2)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        _state())
    r1 = ck.restore(like, step=1)
    s0 = _state(0)
    np.testing.assert_array_equal(np.asarray(r1["params"]["w"]),
                                  np.asarray(s0["params"]["w"]))
    assert ck.manifest(1)["meta"]["arch"] == "phi4"


def test_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(), 1)
    bad = _state()
    bad["params"]["w"] = jnp.zeros((4, 4))
    with pytest.raises(AssertionError):
        ck.restore(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), bad))
