"""Hypothesis property tests on the system's invariants (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st

from repro.configs import ASSIGNED, REGISTRY, SHAPES, supports_shape
from repro.core.activations import gelu_exact, i_gelu
from repro.core.attention import merge_partials, ring_from_full
from repro.kernels import ref
from repro.optim.compression import dequantize_int8, quantize_int8

SET = settings(max_examples=25, deadline=None)


# --------------------------------------------------------------------------
# distributed softmax merge (T4): sharded partials == full softmax
# --------------------------------------------------------------------------

@SET
@given(st.integers(1, 4), st.integers(1, 6), st.integers(2, 4),
       st.integers(0, 2**31 - 1))
def test_merge_partials_equals_full_softmax(b, h, shards, seed):
    """Splitting the KV set into shards, computing per-shard (o, m, l) and
    merging == softmax over the full set.  The paper's T4 invariant."""
    rng = np.random.default_rng(seed)
    S, D = 8 * shards, 16
    q = rng.standard_normal((b, h, D)).astype(np.float32)
    k = rng.standard_normal((b, S, h, D)).astype(np.float32)
    v = rng.standard_normal((b, S, h, D)).astype(np.float32)
    scale = 1.0 / np.sqrt(D)

    # full softmax reference
    want = ref.decode_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                    jnp.asarray(v), S)

    # per-shard partials, merged with the T4 rule (numpy mirror of the
    # cross-device math: pmax/psum over the shard list)
    os_, ms_, ls_ = [], [], []
    for i in range(shards):
        sl = slice(i * 8, (i + 1) * 8)
        s = np.einsum("bhd,bshd->bhs", q * scale, k[:, sl])
        m = s.max(-1)
        p = np.exp(s - m[..., None])
        l = p.sum(-1)
        o = np.einsum("bhs,bshd->bhd", p, v[:, sl])
        os_.append(o), ms_.append(m), ls_.append(l)
    m_all = np.max(ms_, axis=0)
    l_all = sum(l * np.exp(m - m_all) for l, m in zip(ls_, ms_))
    o_all = sum(o * np.exp(m - m_all)[..., None] for o, m in zip(os_, ms_))
    got = o_all / np.maximum(l_all, 1e-30)[..., None]
    np.testing.assert_allclose(got, np.asarray(want), rtol=1e-4, atol=1e-4)


def test_merge_partials_no_axes_normalizes():
    o = jnp.ones((2, 3, 4))
    m = jnp.zeros((2, 3))
    l = jnp.full((2, 3), 2.0)
    out = merge_partials(o, m, l, ())
    np.testing.assert_allclose(np.asarray(out), 0.5)


# --------------------------------------------------------------------------
# ring cache (SWA)
# --------------------------------------------------------------------------

@SET
@given(st.integers(1, 3), st.integers(1, 40), st.integers(1, 5))
def test_ring_cache_slots(b, s, w_log):
    """ring_from_full places position p at slot p % W for the last W
    positions."""
    W = 2 ** w_log
    k = jnp.arange(b * s, dtype=jnp.float32).reshape(b, s, 1, 1)
    ring = np.asarray(ring_from_full(k, W))
    for p in range(max(0, s - W), s):
        np.testing.assert_allclose(ring[:, p % W, 0, 0],
                                   np.asarray(k[:, p, 0, 0]))


# --------------------------------------------------------------------------
# online softmax: order invariance
# --------------------------------------------------------------------------

@SET
@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 32, 64]))
def test_flash_block_size_invariance(seed, block):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, 24, 2, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 48, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 48, 2, 16)), jnp.float32)
    a = ref.flash_attention_ref(q, k, v, causal=True, block_kv=block)
    b_ = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# int8 quantization (gradient compression)
# --------------------------------------------------------------------------

@SET
@given(st.integers(0, 2**31 - 1), st.floats(1e-3, 1e3))
def test_quantize_int8_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(256) * scale, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    amax = float(np.abs(np.asarray(x)).max())
    assert err.max() <= amax / 127.0 * 0.5 + 1e-6 * amax


# --------------------------------------------------------------------------
# i-GELU approximation (paper T5)
# --------------------------------------------------------------------------

@SET
@given(st.integers(0, 2**31 - 1))
def test_i_gelu_error_bound(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-6, 6, 512), jnp.float32)
    err = np.abs(np.asarray(i_gelu(x)) - np.asarray(gelu_exact(x)))
    assert err.max() < 0.02        # I-BERT's published bound is ~0.01


# --------------------------------------------------------------------------
# config invariants (all 10 assigned archs)
# --------------------------------------------------------------------------

def test_assigned_arch_count():
    assert len(ASSIGNED) == 10


def test_config_divisibility_for_production_mesh():
    """Every assigned arch must shard on the (16,16) production mesh."""
    for name, cfg in ASSIGNED.items():
        assert cfg.d_model % 16 == 0, name
        assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= cfg.vocab
        if cfg.d_ff:
            assert cfg.d_ff % 16 == 0, name
        if cfg.has_attention:
            hhd = cfg.n_heads * cfg.head_dim
            assert hhd % 16 == 0, name
            assert (cfg.n_kv_heads * cfg.head_dim) % 16 == 0, name
            if cfg.attention_sharding == "head_tp":
                assert cfg.n_heads % 16 == 0, name
        if cfg.ssm_state:
            assert cfg.padded_ssm_heads() % 16 == 0, name
        total = sum(c for _, c in cfg.schedule)
        assert total == cfg.n_layers, (name, total, cfg.n_layers)


def test_param_counts_sane():
    """Param counts within 20% of the published sizes."""
    expected = {
        "phi4-mini-3.8b": 3.8e9, "chatglm3-6b": 6e9, "deepseek-67b": 67e9,
        "gemma3-27b": 27e9, "mixtral-8x22b": 141e9, "mixtral-8x7b": 47e9,
        "internvl2-76b": 76e9, "hymba-1.5b": 1.5e9, "mamba2-2.7b": 2.7e9,
    }
    for name, want in expected.items():
        got = ASSIGNED[name].n_params()
        assert 0.75 * want < got < 1.35 * want, (name, got, want)


def test_shape_support_matrix():
    """40 cells; long_500k runs only for sub-quadratic-capable archs."""
    cells = [(a, s) for a in ASSIGNED for s in SHAPES]
    assert len(cells) == 40
    skips = {a for a in ASSIGNED
             if not supports_shape(ASSIGNED[a], SHAPES["long_500k"])}
    assert skips == {"phi4-mini-3.8b", "chatglm3-6b", "deepseek-67b",
                     "internvl2-76b", "whisper-base"}


def test_reduced_configs_instantiable():
    for name, cfg in REGISTRY.items():
        r = cfg.reduced()
        assert r.n_params() > 0
        assert sum(c for _, c in r.schedule) == r.n_layers
