"""Multi-device equivalence program (run by test_distributed.py in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8).

Checks, all against the unsharded reference with identical init/batch:
  1. train_step loss/grad-norm/params exact on a (2,2) mesh (head_tp arch)
  2. same for seq_sp, ssm and unaligned-kv archs
  3. prefill+decode token trajectory on a mesh == unsharded
  4. multi-pod (2,2,2) train exact; int8-compressed within quantization tol
  5. tree reduce-scatter == ring psum_scatter
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import ShapeConfig, get_config
from repro.core.precision import FP32
from repro.launch import steps
from repro.launch.mesh import make_test_mesh
from repro.models import frontends, lm


def check(name, ok):
    print(("PASS " if ok else "FAIL ") + name, flush=True)
    if not ok:
        sys.exit(1)


def train_equiv(arch, mesh_shape, axes=("data", "model"), tol=5e-4,
                **kwargs):
    shape = ShapeConfig("t", "train", 32, 4)
    cfg = get_config(arch).reduced()
    batch = frontends.make_batch(cfg, "train", 4,
                                 32 + (cfg.n_patches or 0), seed=1)
    b0 = steps.make_train_step(cfg, shape, None, policy=FP32)
    s0 = b0.aux["init_state"](0)
    s0, m0 = b0.fn(s0, batch)
    mesh = make_test_mesh(mesh_shape, axes)
    b1 = steps.make_train_step(cfg, shape, mesh, policy=FP32, **kwargs)
    s1 = b1.aux["init_state"](0)
    s1, m1 = b1.fn(s1, batch)
    dl = abs(float(m0["loss"]) - float(m1["loss"]))
    dg = abs(float(m0["grad_norm"]) - float(m1["grad_norm"]))
    dp = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
             for a, b in zip(jax.tree.leaves(s0["params"]),
                             jax.tree.leaves(s1["params"])))
    return dl < tol and dg < max(tol * float(m0["grad_norm"]), tol) \
        and dp < 1e-6, (dl, dg, dp)


def decode_equiv(arch, mesh_shape):
    cfg = get_config(arch).reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    batch = frontends.make_batch(cfg, "prefill", 4,
                                 32 + (cfg.n_patches or 0), seed=2)
    from repro.sharding.plan import UNSHARDED
    t0, c0, p0 = lm.forward_prefill(params, batch, plan=UNSHARDED, cfg=cfg,
                                    policy=FP32, max_seq=64)
    toks0 = [np.asarray(t0)]
    t, p, c = t0, p0, c0
    for _ in range(4):
        t, c = lm.forward_decode(params, t, p, c, plan=UNSHARDED, cfg=cfg,
                                 policy=FP32)
        p = p + 1
        toks0.append(np.asarray(t))
    mesh = make_test_mesh(mesh_shape)
    pshape = ShapeConfig("p", "prefill", 32, 4)
    dshape = ShapeConfig("d", "decode", 64, 4)
    bp = steps.make_prefill_step(cfg, pshape, mesh, policy=FP32, max_seq=64)
    bd = steps.make_decode_step(cfg, dshape, mesh, policy=FP32, max_seq=64)
    t1, c1, p1 = bp.fn(params, batch)
    agree = int((np.asarray(t1) == toks0[0]).all())
    t, p, c = t1, p1, c1
    for i in range(4):
        t, p, c = bd.fn(params, t, p, c)
        agree += int((np.asarray(t) == toks0[i + 1]).all())
    return agree >= 4, agree          # allow one fp tie flip


def main():
    ok, info = train_equiv("deepseek-67b", (2, 2))
    check(f"train head_tp aligned {info}", ok)
    ok, info = train_equiv("chatglm3-6b", (1, 4))
    check(f"train head_tp unaligned-kv {info}", ok)
    ok, info = train_equiv("phi4-mini-3.8b", (2, 2))
    check(f"train seq_sp {info}", ok)
    ok, info = train_equiv("mamba2-2.7b", (2, 2))
    check(f"train ssm {info}", ok)
    ok, info = train_equiv("whisper-base", (2, 2))
    check(f"train encdec {info}", ok)
    ok, info = train_equiv("mixtral-8x7b", (2, 2), tol=5e-3)
    check(f"train moe {info}", ok)

    ok, info = train_equiv("deepseek-67b", (2, 2, 2),
                           ("pod", "data", "model"))
    check(f"train multipod {info}", ok)
    ok, info = train_equiv("deepseek-67b", (2, 2, 2),
                           ("pod", "data", "model"), tol=5e-3,
                           grad_compression="int8")
    check(f"train multipod int8 {info}", ok)
    ok, info = train_equiv("deepseek-67b", (2, 2), reduce_method="tree")
    check(f"train tree-reduce {info}", ok)

    for arch in ("deepseek-67b", "gemma3-27b", "mamba2-2.7b", "hymba-1.5b",
                 "whisper-base"):
        ok, agree = decode_equiv(arch, (2, 2))
        check(f"decode {arch} agree={agree}/5", ok)

    # ---- §Perf variant stacks stay exact -------------------------------
    cfg = get_config("deepseek-67b").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    batch = frontends.make_batch(cfg, "prefill", 4, 32, seed=2)
    from repro.sharding.plan import UNSHARDED
    from repro.core.precision import BF16
    t0, _, _ = lm.forward_prefill(params, batch, plan=UNSHARDED, cfg=cfg,
                                  policy=BF16, max_seq=64)
    mesh = make_test_mesh((2, 2))
    bp = steps.make_prefill_step(
        cfg, ShapeConfig("p", "prefill", 32, 4), mesh, policy=BF16,
        max_seq=64, attention_sharding="seq_sp", comm_fp8=True,
        mlp_weight_stationary=True)
    t1, _, _ = bp.fn(params, batch)
    check("P3 variant (seq_sp+comm_fp8+mlp_ws) prefill",
          (np.asarray(t1) == np.asarray(t0)).all())

    cfg2 = get_config("mamba2-2.7b").reduced()
    params2 = lm.init_lm(jax.random.key(0), cfg2, jnp.float32)
    batch2 = frontends.make_batch(cfg2, "prefill", 4, 32, seed=5)
    t0, c0, p0 = lm.forward_prefill(params2, batch2, plan=UNSHARDED,
                                    cfg=cfg2, policy=FP32, max_seq=64)
    bp2 = steps.make_prefill_step(cfg2, ShapeConfig("p", "prefill", 32, 4),
                                  mesh, policy=FP32, max_seq=64,
                                  ssm_seq_parallel=True)
    bd2 = steps.make_decode_step(cfg2, ShapeConfig("d", "decode", 64, 4),
                                 mesh, policy=FP32, max_seq=64)
    t1, c1, p1 = bp2.fn(params2, batch2)
    t1d, _, _ = bd2.fn(params2, t1, p1, c1)
    t0d, _ = lm.forward_decode(params2, t0, p0, c0, plan=UNSHARDED,
                               cfg=cfg2, policy=FP32)
    check("P2 variant (seq-parallel SSD) prefill+decode",
          (np.asarray(t1) == np.asarray(t0)).all()
          and (np.asarray(t1d) == np.asarray(t0d)).all())

    # fp8 KV cache: decode must track the reference within fp8 tolerance
    bp3 = steps.make_prefill_step(cfg, ShapeConfig("p", "prefill", 32, 4),
                                  mesh, policy=BF16, max_seq=64,
                                  kv_cache_dtype="float8_e4m3fn")
    bd3 = steps.make_decode_step(cfg, ShapeConfig("d", "decode", 64, 4),
                                 mesh, policy=BF16, max_seq=64,
                                 kv_cache_dtype="float8_e4m3fn")
    tq, cq, pq = bp3.fn(params, batch)
    tqd, _, _ = bd3.fn(params, tq, pq, cq)
    check("P1 variant (fp8 KV cache) runs and decodes",
          np.asarray(tqd).shape == (4,))

    # long-context-style plan: batch=1, cache over the whole mesh
    cfg = get_config("mamba2-2.7b").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    batch = frontends.make_batch(cfg, "prefill", 1, 32, seed=4)
    from repro.sharding.plan import UNSHARDED
    t0, c0, p0 = lm.forward_prefill(params, batch, plan=UNSHARDED, cfg=cfg,
                                    policy=FP32, max_seq=64)
    t0d, _ = lm.forward_decode(params, t0, p0, c0, plan=UNSHARDED, cfg=cfg,
                               policy=FP32)
    mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
    pshape = ShapeConfig("p", "prefill", 32, 1)
    dshape = ShapeConfig("d", "decode", 64, 1)
    bp = steps.make_prefill_step(cfg, pshape, mesh, policy=FP32, max_seq=64)
    bd = steps.make_decode_step(cfg, dshape, mesh, policy=FP32, max_seq=64)
    t1, c1, p1 = bp.fn(params, batch)
    t1d, _, _ = bd.fn(params, t1, p1, c1)
    check("long-context batch=1 full-mesh decode",
          (np.asarray(t1) == np.asarray(t0)).all()
          and (np.asarray(t1d) == np.asarray(t0d)).all())

    # fused-epilogue pipeline parity under tp>1 sharding: fused (default)
    # and unfused steps on the same mesh must produce identical greedy
    # trajectories (prologue norms fold behind gathers, residual adds sit
    # after the tp-partial reductions — both exact transformations)
    cfg = get_config("deepseek-67b").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    batch = frontends.make_batch(cfg, "prefill", 4, 32, seed=6)
    mesh = make_test_mesh((2, 2))
    pshape = ShapeConfig("p", "prefill", 32, 4)
    dshape = ShapeConfig("d", "decode", 64, 4)
    toks = {}
    for fuse in (True, False):
        bp = steps.make_prefill_step(cfg, pshape, mesh, policy=FP32,
                                     max_seq=64, fuse_epilogues=fuse)
        bd = steps.make_decode_step(cfg, dshape, mesh, policy=FP32,
                                    max_seq=64, fuse_epilogues=fuse)
        t, c, p = bp.fn(params, batch)
        out = [np.asarray(t)]
        for _ in range(3):
            t, p, c = bd.fn(params, t, p, c)
            out.append(np.asarray(t))
        toks[fuse] = out
    agree = sum(int((a == b).all())
                for a, b in zip(toks[True], toks[False]))
    # ref-path fusion is bit-identical, so ties resolve identically —
    # demand exact agreement, no tie allowance
    check(f"fused-epilogue tp>1 parity agree={agree}/4", agree == 4)

    print("ALL OK", flush=True)


if __name__ == "__main__":
    main()
