"""Prefix cache: radix index + refcounted COW block sharing + LRU eviction.

The load-bearing property is *token identity*: a warm admission that reuses
cached prefix blocks must produce exactly the tokens a cold prefill would —
greedy AND sampled, across paper archs, including chunked admission,
preemption-recompute, and speculative decoding.  KV for position p depends
only on tokens [0, p] (causal attention), so sharing is exact by
construction as long as the write discipline holds: a slot only ever writes
a block it owns at refcount 1 (fresh alloc or copy-on-write duplicate).
These tests pin the discipline at every layer — allocator refcounts, radix
lookup/insert/evict, engine admission — plus a hypothesis property test
driving random admit/retire/pressure sequences against the refcount
invariants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.precision import FP32
from repro.models import lm
from repro.serving import (ChunkedPrefillPolicy, InferenceEngine, PrefixCache,
                           Request, SamplingParams, SpecConfig, make_policy)
from repro.serving.kv_cache import BlockAllocator

# --------------------------------------------------------------------------
# BlockAllocator refcounts
# --------------------------------------------------------------------------


def test_allocator_retain_release():
    a = BlockAllocator(num_blocks=4, block_size=2)
    x = a.alloc(2)
    assert [a.refcount(b) for b in x] == [1, 1]
    a.retain(x)
    assert [a.refcount(b) for b in x] == [2, 2]
    a.free(x)                       # drops to 1: still allocated
    assert a.num_used == 2
    a.free(x)                       # drops to 0: back on the free list
    assert a.num_used == 0
    with pytest.raises(RuntimeError, match="double free"):
        a.free(x)


def test_allocator_retain_unallocated_raises():
    a = BlockAllocator(num_blocks=2, block_size=2)
    with pytest.raises(RuntimeError, match="retain"):
        a.retain([0])
    with pytest.raises(ValueError):
        a.alloc(-1)
    with pytest.raises(ValueError):
        BlockAllocator(num_blocks=0, block_size=2)


def test_allocator_reclaim_hook():
    """alloc() consults the reclaim hook before failing, and only takes
    what the hook actually freed back."""
    a = BlockAllocator(num_blocks=4, block_size=2)
    held = a.alloc(4)
    calls = []

    def reclaim(shortfall):
        calls.append(shortfall)
        give = held[:min(shortfall, 2)]
        del held[:len(give)]
        a.free(give)
        return len(give)

    a.reclaim = reclaim
    got = a.alloc(2)                # hook frees 2 -> satisfied
    assert got is not None and calls == [2]
    assert a.alloc(3) is None       # hook frees 2, still short of 3
    assert calls == [1, 3][1:] or calls[-1] == 3


# --------------------------------------------------------------------------
# radix index: lookup / insert / evict (host-side, no engine)
# --------------------------------------------------------------------------


def _cache(num_blocks=16, bs=4, **kw):
    a = BlockAllocator(num_blocks=num_blocks, block_size=bs)
    return a, PrefixCache(a, bs, **kw)


def test_lookup_insert_longest_prefix():
    a, pc = _cache()
    blk = a.alloc(3)
    toks = list(range(1, 12))           # 11 tokens: 2 full blocks + tail(3)
    pc.insert(toks, blk)
    pc.check()
    assert pc.cached_blocks == 3
    # exact prefix: full blocks then the partial tail
    got, n = pc.lookup(toks)
    assert n == 11 and got == blk
    # diverging inside the second block: only the first full block matches
    q = toks[:5] + [99] * 6
    got, n = pc.lookup(q)
    assert n == 5 and got[0] == blk[0] and len(got) == 2
    # the partial use of block 1 matched 1 extra token (position 4)
    assert got[1] == blk[1]
    # a miss from token zero
    assert pc.lookup([99, 98, 97])[1] == 0
    # limit caps the match even when more is cached
    got, n = pc.lookup(toks, limit=6)
    assert n == 6 and len(got) == 2


def test_lookup_partial_of_full_block():
    """A full-block child matched only partway is still a hit — causality
    makes its leading positions valid for the shorter query."""
    a, pc = _cache()
    blk = a.alloc(2)
    pc.insert(list(range(8)), blk)          # two full blocks
    got, n = pc.lookup([0, 1, 2, 3, 4, 5, 70, 71])
    assert n == 6 and got == blk            # 4 exact + 2 into block 1


def test_insert_dedup_first_writer_wins():
    a, pc = _cache()
    b1, b2 = a.alloc(2), a.alloc(2)
    toks = list(range(7))
    pc.insert(toks, b1)
    pc.insert(toks, b2)                     # same content: no new nodes
    pc.check()
    assert pc.cached_blocks == 2
    assert pc.lookup(toks)[0] == b1
    assert a.refcount(b2[0]) == 1           # duplicate content not retained


def test_hash_collision_reads_as_miss(monkeypatch):
    """Edges are keyed by a rolling hash of block content; verification
    against the stored token tuple must make a colliding entry a miss (or a
    dedup non-match on insert), never a wrong share.  Force every hash to
    collide and confirm distinct contents still index and resolve apart."""
    from repro.serving import prefix_cache as pcm
    monkeypatch.setattr(pcm, "_rhash", lambda toks, h=0: 7)
    a, pc = _cache()
    b1, b2 = a.alloc(2), a.alloc(2)
    t1 = list(range(7))
    t2 = [50 + t for t in range(7)]         # same lengths, same (forced) hash
    pc.insert(t1, b1)
    pc.insert(t2, b2)                       # collides at every edge
    pc.check()
    assert pc.cached_blocks == 4            # both indexed despite collision
    assert pc.lookup(t1) == (b1, 7)
    assert pc.lookup(t2) == (b2, 7)
    assert pc.lookup([99, 98, 97])[1] == 0  # colliding probe: clean miss
    # eviction unlinks the right node out of a shared bucket
    a.free(b1), a.free(b2)                  # drop our refs: index-only now
    assert pc.clear() == 4
    pc.check()
    assert pc.cached_blocks == 0 and a.num_used == 0


def test_lru_eviction_order_and_reclaim():
    a, pc = _cache(num_blocks=6, bs=4)
    b1, b2 = a.alloc(1), a.alloc(1)
    pc.insert([1, 2, 3, 4], b1)
    pc.insert([5, 6, 7, 8], b2)
    a.free(b1)
    a.free(b2)                   # both index-only now (refcount 1)
    pc.lookup([1, 2, 3, 4])      # touch b1: b2 becomes LRU
    got = a.alloc(5)             # 4 free + 1 via reclaim -> evicts b2
    assert got is not None
    pc.check()
    assert pc.cached_blocks == 1 and pc.evicted_blocks == 1
    assert pc.lookup([5, 6, 7, 8], record=False)[1] == 0    # b2 gone
    assert pc.lookup([1, 2, 3, 4], record=False)[1] == 4    # b1 survives


def test_eviction_skips_pinned_blocks():
    a, pc = _cache(num_blocks=2, bs=4)
    b1 = a.alloc(1)
    pc.insert([1, 2, 3, 4], b1)  # refcount 2: caller + index
    assert a.alloc(2) is None    # b1 pinned by its holder: nothing to evict
    a.free(b1)                   # index-only now
    assert a.alloc(2) is not None     # reclaim evicts it
    assert pc.cached_blocks == 0


def test_max_blocks_cap():
    a, pc = _cache(num_blocks=16, bs=4, max_blocks=2)
    for i in range(4):
        b = a.alloc(1)
        pc.insert([10 * i + j for j in range(4)], b)
        a.free(b)
        pc.check()
    assert pc.cached_blocks <= 2
    assert pc.evicted_blocks >= 2


def test_cache_parameter_validation():
    a = BlockAllocator(num_blocks=4, block_size=4)
    with pytest.raises(ValueError, match="max_blocks"):
        PrefixCache(a, 4, max_blocks=-1)
    pc = PrefixCache(a, 4)
    with pytest.raises(ValueError, match="cannot cover"):
        pc.insert(list(range(9)), a.alloc(1))


# --------------------------------------------------------------------------
# engine-level identity: warm admission == cold prefill
# --------------------------------------------------------------------------

_PARAMS_CACHE = {}


def _reduced(arch):
    if arch not in _PARAMS_CACHE:
        cfg = get_config(arch).reduced()
        _PARAMS_CACHE[arch] = (cfg, lm.init_lm(jax.random.key(0), cfg,
                                               jnp.float32))
    return _PARAMS_CACHE[arch]


def _shared_trace(cfg, n=4, *, uid0=0, pre_len=40, max_new=6, sampled=(),
                  seed=7):
    """n requests sharing a `pre_len`-token system prompt + unique tails.
    Request i's sampling seed is i (not uid), so re-submitted copies with
    shifted uids reproduce identical token streams."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab, pre_len, dtype=np.int32)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab, 4 + i, dtype=np.int32)
        reqs.append(Request(
            uid=uid0 + i,
            prompt=np.concatenate([shared, tail]) if i else shared.copy(),
            max_new_tokens=max_new,
            sampling=SamplingParams(temperature=0.8, top_k=8, seed=i)
            if i in sampled else SamplingParams()))
    return reqs


def _run(cfg, params, reqs, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("max_seq", 64)
    engine = InferenceEngine(cfg, params, policy=FP32, **kw)
    for r in reqs:
        engine.submit(r)
    done = {t.uid: t.output for t in engine.run()}
    return engine, done


def _drained(engine):
    """With the cache on, a drained engine's only live blocks are the
    index's: free + cached == pool, and every indexed block is index-only."""
    alloc, pc = engine.allocator, engine.prefix_cache
    assert alloc.num_free == alloc.num_blocks - pc.cached_blocks
    assert all(alloc.refcount(b) == 1 for b in pc.index_blocks())
    pc.check()


@pytest.mark.parametrize("arch", ["gpt-j", "gpt3-xl", "phi4-mini-3.8b",
                                  "chatglm3-6b"])
def test_warm_identity_greedy_and_sampled(arch):
    """A fully warmed cache serves every request token-identically to cold
    prefill, computing strictly fewer prompt tokens."""
    cfg, params = _reduced(arch)
    mk = lambda uid0, : _shared_trace(cfg, uid0=uid0, sampled=(1, 3))
    base = _run(cfg, params, mk(0), prefix_cache=False)[1]
    eng, got1 = _run(cfg, params, mk(0), prefix_cache=True,
                     kv_pool_blocks=24)
    assert got1 == base, f"{arch}: first (cold-ish) pass diverged"
    cold_nar = eng.stats().nar_tokens
    eng.reset_stats()
    for r in mk(100):
        eng.submit(r)
    got2 = {t.uid - 100: t.output for t in eng.run()}
    assert got2 == base, f"{arch}: warm pass diverged"
    st = eng.stats()
    assert st.prefix_hits == 4 and st.prefix_cache_hit_rate == 1.0
    assert st.cached_prefix_tokens > 0
    assert st.nar_tokens < cold_nar         # strictly fewer computed
    _drained(eng)


def test_in_batch_sharing_first_pass():
    """Prefix blocks are indexed the moment a prompt's KV lands, so later
    requests in the SAME trace already hit the shared system prompt."""
    cfg, params = _reduced("phi4-mini-3.8b")
    base = _run(cfg, params, _shared_trace(cfg), prefix_cache=False)[1]
    eng, got = _run(cfg, params, _shared_trace(cfg), prefix_cache=True,
                    kv_pool_blocks=24)
    assert got == base
    st = eng.stats()
    assert st.prefix_hits >= 2 and st.cached_prefix_tokens >= 80
    _drained(eng)


def test_cow_on_shared_partial_tail():
    """A hit ending mid-block duplicates the shared tail before the suffix
    writes into it — and the second sharer still decodes identically."""
    cfg, params = _reduced("gpt-j")
    rng = np.random.default_rng(3)
    p = rng.integers(0, cfg.vocab, 18, dtype=np.int32)     # 18 = 16 + 2
    first = [Request(uid=0, prompt=p.copy(), max_new_tokens=6)]
    eng, done = _run(cfg, params, first, prefix_cache=True, block_size=16,
                     kv_pool_blocks=16)
    # retirement indexed [0, pos): 18 + 5 committed tokens incl. a partial
    # tail block; a prompt extending into that tail must COW it
    p2 = np.concatenate([p, np.asarray(done[0][:2], np.int32)])
    second = [Request(uid=1, prompt=p2, max_new_tokens=6)]
    base = _run(cfg, params,
                [Request(uid=1, prompt=p2.copy(), max_new_tokens=6)],
                prefix_cache=False)[1]
    for r in second:
        eng.submit(r)
    got = {t.uid: t.output for t in eng.run()}
    st = eng.stats()
    assert got[1] == base[1]
    assert st.cow_copies >= 1
    assert st.cached_prefix_tokens >= 18
    _drained(eng)


def test_boundary_prompt_indexes_only_landed_kv():
    """Regression: prompt_len % bs == bs - 1.  Landing appends the first
    sampled token to task.output before the blocks are indexed, so naive
    full_len indexing would publish a "full" block whose last position's
    KV only lands on the NEXT decode step — a later prompt extending
    across that boundary would then attend to never-written KV."""
    cfg, params = _reduced("gpt-j")
    rng = np.random.default_rng(5)
    p = rng.integers(0, cfg.vocab, 31, dtype=np.int32)     # 31 = 2*16 - 1
    eng = InferenceEngine(cfg, params, policy=FP32, batch_size=2,
                          max_seq=64, block_size=16, kv_pool_blocks=16,
                          prefix_cache=True)
    eng.submit(Request(uid=0, prompt=p.copy(), max_new_tokens=6))
    eng.step()                       # prefill lands; one token sampled
    out = eng.runner.slots[0].output if eng.runner.slots[0] else []
    assert len(out) >= 1
    # mid-flight the index may cover the first block only: position 31
    # (the sampled token) has no KV in the pool yet
    _, matched = eng.prefix_cache.lookup(
        np.concatenate([p, np.asarray(out, np.int32)]),
        touch=False, record=False)
    assert matched <= 16, f"index published {matched} tokens of unlanded KV"
    done = {t.uid: t.output for t in eng.run()}
    # after retirement the boundary block HAS landed ([0, pos) indexed):
    # a prompt continuing through it must be a hit and stay identical
    p2 = np.concatenate([p, np.asarray(done[0][:2], np.int32)])
    base = _run(cfg, params,
                [Request(uid=1, prompt=p2.copy(), max_new_tokens=6)],
                prefix_cache=False)[1]
    eng.submit(Request(uid=1, prompt=p2, max_new_tokens=6))
    got = {t.uid: t.output for t in eng.run()}
    assert got[1] == base[1]
    assert eng.stats().cached_prefix_tokens >= 31
    _drained(eng)


def test_chunked_policy_warm_identity():
    """Cache + ChunkedPrefillPolicy: cached long prompts park with
    prefilled = hit and the chunk budget loop finishes the suffix."""
    cfg, params = _reduced("phi4-mini-3.8b")
    mk = lambda uid0: _shared_trace(cfg, uid0=uid0, sampled=(2,))
    base = _run(cfg, params, mk(0), prefix_cache=False)[1]
    eng, got1 = _run(cfg, params, mk(0), prefix_cache=True,
                     kv_pool_blocks=24,
                     scheduler=ChunkedPrefillPolicy(16))
    assert got1 == base
    for r in mk(100):
        eng.submit(r)
    got2 = {t.uid - 100: t.output for t in eng.run()}
    assert got2 == base
    assert eng.stats().prefix_hits >= 4
    _drained(eng)


def test_preemption_recompute_warm_identity():
    """A starved pool with the cache on: preempted requests re-admit as
    cache hits (their released blocks stay indexed) and still finish
    token-identically; nothing leaks."""
    cfg, params = _reduced("phi4-mini-3.8b")
    mk = lambda: _shared_trace(cfg, pre_len=20, max_new=9, sampled=(1, 3))
    base = _run(cfg, params, mk(), prefix_cache=False)[1]
    eng, got = _run(cfg, params, mk(), prefix_cache=True,
                    block_size=8, kv_pool_blocks=6)
    st = eng.stats()
    assert st.preemptions > 0
    assert got == base
    _drained(eng)


def test_spec_decode_warm_identity():
    """Cache + speculative decoding: warm admissions draft-prefill at
    suffix landing and verify/rollback respects shared-block refcounts."""
    cfg, params = _reduced("gpt-j")
    mk = lambda uid0: _shared_trace(cfg, uid0=uid0, pre_len=24, max_new=8,
                                    sampled=(1,))
    base = _run(cfg, params, mk(0), prefix_cache=False)[1]
    spec = SpecConfig(draft="auto", k=3, draft_seed=1234)   # rejection-heavy
    eng, got1 = _run(cfg, params, mk(0), prefix_cache=True,
                     kv_pool_blocks=24, spec=spec)
    assert got1 == base
    for r in mk(100):
        eng.submit(r)
    got2 = {t.uid - 100: t.output for t in eng.run()}
    assert got2 == base
    st = eng.stats()
    assert st.spec_rounds > 0 and st.prefix_hits >= 4
    _drained(eng)


def test_cache_aware_admission_order():
    """With cache_aware on, a mostly-cached request jumps a cold one in the
    admission order (stable within equal cached lengths)."""
    cfg, params = _reduced("gpt-j")
    rng = np.random.default_rng(11)
    warm_p = rng.integers(0, cfg.vocab, 32, dtype=np.int32)
    cold_p = rng.integers(0, cfg.vocab, 32, dtype=np.int32)
    for aware, expect_warm_first in ((True, True), (False, False)):
        eng, _ = _run(cfg, params,
                      [Request(uid=0, prompt=warm_p.copy(),
                               max_new_tokens=4)],
                      prefix_cache=True, batch_size=1, kv_pool_blocks=12,
                      scheduler=make_policy("fcfs", cache_aware=aware))
        cold = Request(uid=1, prompt=cold_p.copy(), max_new_tokens=4)
        warm = Request(uid=2, prompt=warm_p.copy(), max_new_tokens=4)
        eng.submit(cold)
        eng.submit(warm)       # arrives second; cached almost entirely
        eng.run()
        assert (warm._seq < cold._seq) == expect_warm_first, f"{aware=}"


def test_unsupported_arch_disables_with_reason():
    for arch in ("mamba2-2.7b", "gemma3-27b"):
        cfg = get_config(arch).reduced()
        params = lm.init_lm(jax.random.key(1), cfg, jnp.float32)
        eng = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                              policy=FP32, prefix_cache=True)
        assert eng.prefix_cache is None
        assert eng.runner.prefix_cache_reason
        for r in _shared_trace(cfg, 2, pre_len=12, max_new=3):
            eng.submit(r)
        assert len(eng.run()) == 2      # serves fine, just cold


def test_stats_fields_and_summary():
    cfg, params = _reduced("gpt-j")
    eng, _ = _run(cfg, params, _shared_trace(cfg), prefix_cache=True,
                  kv_pool_blocks=24)
    st = eng.stats()
    d = st.to_dict()
    for key in ("prefix_lookups", "prefix_hits", "prefix_cache_hit_rate",
                "cached_prefix_tokens", "cached_blocks", "evicted_blocks",
                "cow_copies"):
        assert key in d
    assert 0.0 <= st.prefix_cache_hit_rate <= 1.0
    assert st.prefix_lookups >= st.prefix_hits > 0
    assert "PREFIX" in st.summary()
    # reset re-bases the cumulative cache counters
    eng.reset_stats()
    st2 = eng.stats()
    assert st2.prefix_lookups == 0 and st2.cached_prefix_tokens == 0
    assert st2.cached_blocks == eng.prefix_cache.cached_blocks


# --------------------------------------------------------------------------
# hypothesis property: refcount-consistent state under random sequences
# --------------------------------------------------------------------------

def test_random_admit_retire_pressure_invariants():
    hyp = pytest.importorskip(
        "hypothesis", reason="hypothesis not installed in this env")
    from hypothesis import given, settings, strategies as st_

    BS, NB = 4, 10

    op = st_.tuples(st_.integers(0, 2), st_.integers(0, 2 ** 30))

    @settings(max_examples=40, deadline=None)
    @given(st_.lists(op, min_size=1, max_size=50))
    def run(ops):
        alloc = BlockAllocator(num_blocks=NB, block_size=BS)
        pc = PrefixCache(alloc, BS)
        holders = []        # (table, tokens) — simulated live slots

        def invariants():
            pc.check()
            idx = pc.index_blocks()
            assert len(idx) == pc.cached_blocks
            for blk in range(NB):
                want = sum(t.count(blk) for t, _ in holders)
                want += 1 if blk in idx else 0
                assert alloc.refcount(blk) == want, (blk, ops)
            assert alloc.num_used == sum(
                1 for b in range(NB) if alloc.refcount(b) > 0)

        for kind, v in ops:
            if kind == 0:       # admit: lookup -> retain -> alloc -> COW
                n_tok = 1 + v % 14
                toks = [(v >> j) & 1 for j in range(n_tok)]  # tiny alphabet
                blocks, hit = pc.lookup(toks, limit=max(1, n_tok - 1))
                alloc.retain(blocks)
                partial = hit % BS != 0
                need = (-(-n_tok // BS)) - len(blocks) + (1 if partial
                                                          else 0)
                new = alloc.alloc(need)
                if new is None:
                    alloc.free(blocks)
                else:
                    table = list(blocks)
                    if partial:
                        alloc.free([table[-1]])
                        table[-1] = new[0]
                        new = new[1:]
                    table.extend(new)
                    holders.append((table, toks))
            elif kind == 1 and holders:     # retire: insert then release
                table, toks = holders.pop(v % len(holders))
                pc.insert(toks, table)
                alloc.free(table)
            else:               # pressure: force reclaim, then give back
                got = alloc.alloc(1 + v % NB)
                if got is not None:
                    alloc.free(got)
            invariants()

    run()
