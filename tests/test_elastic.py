"""Elastic scaling: a checkpoint written on one mesh resumes on another
device count (subprocess isolates the forced-device XLA config)."""
import os
import subprocess
import sys
import textwrap

import pytest


@pytest.mark.timeout(600)
def test_elastic_restore_across_device_counts(tmp_path):
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import sys
        sys.path.insert(0, {os.path.join(os.path.dirname(__file__), '..', 'src')!r})
        import jax, numpy as np
        from repro.configs import get_config, ShapeConfig
        from repro.launch import steps
        from repro.launch.mesh import make_test_mesh
        from repro.models import frontends
        from repro.checkpoint import Checkpointer
        from repro.runtime.elastic import elastic_restore
        from repro.core.precision import FP32

        cfg = get_config("phi4-mini-3.8b").reduced()
        shape = ShapeConfig("t", "train", 32, 8)
        batch = frontends.make_batch(cfg, "train", 8, 32, seed=1)

        # train 2 steps on a (4, 2) mesh, checkpoint
        mesh_a = make_test_mesh((4, 2))
        ba = steps.make_train_step(cfg, shape, mesh_a, policy=FP32)
        sa = ba.aux["init_state"](0)
        for _ in range(2):
            sa, ma = ba.fn(sa, batch)
        ck = Checkpointer({str(tmp_path / 'ck')!r})
        ck.save(sa, 2)

        # resume on a (2, 2) mesh (half the devices) and keep training
        mesh_b = make_test_mesh((2, 2))
        bb, sb = elastic_restore(ck, cfg, shape, mesh=mesh_b, policy=FP32)
        assert int(np.asarray(sb["step"])) == 2
        sb, mb = bb.fn(sb, batch)

        # reference: uninterrupted 3 steps on mesh_a
        sr = ba.aux["init_state"](0)
        for _ in range(3):
            sr, mr = ba.fn(sr, batch)
        dl = abs(float(mr["loss"]) - float(mb["loss"]))
        assert dl < 5e-5, ("elastic-resume loss mismatch", dl)
        print("ELASTIC OK", dl)
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=550, env=env)
    sys.stdout.write(p.stdout)
    assert p.returncode == 0, p.stderr[-3000:]
    assert "ELASTIC OK" in p.stdout
